"""MegaServe benchmarks: continuous-vs-static and paged-vs-gathered decode.

Default mode replays a mixed-length Poisson-arrival workload through both
engines (same model, same requests, same arrival process; each warmed up on
an arrival-at-zero copy, then timed on a fresh replay), and cross-checks the
offline simkit projection of the same trace.

``--sweep`` additionally runs the decode-latency-vs-max_len sweep: the *same
live workload* (fixed prompt/budget mix, so fixed live kv_len) is served out
of pools of growing ``max_len``, once per decode path.  The gathered-dense
oracle pays O(max_len) HBM traffic per decode step (gather + full-width
attention), so its step time grows with the pool; the paged path walks block
tables sliced to the live high-water mark, so its step time tracks kv_len and
stays flat.  On attention-only families it also runs the speculative-decoding
sweep: plain paged decode vs n-gram prompt-lookup speculation (friendly
regime, gated at >= 1.3x tokens/s) vs an always-wrong adversarial drafter
(hostile regime, gated at >= 0.9x — draft-length adaptation must shut
speculation off).

``--prefill-sweep`` compares flash vs dense prefill per prompt length:
measured ref-path parity (token-identical streams) + deterministic score-op
accounting gates (band vs full matrix >= 1.5x; chunked-flash kv_len
tracking).  ``--coldstart`` times cold-vs-warm start-to-first-token through
the persistent compile cache (warm must be >= 2x faster with 0 cache
misses).  Results (and the headline comparison) are persisted to ``--out``
(``BENCH_serve.json``) so the perf trajectory is recorded per PR.

    PYTHONPATH=src python benchmarks/serve_bench.py --arch qwen2-0.5b --smoke \
        --requests 24 --rate 150 --slots 4
    PYTHONPATH=src python benchmarks/serve_bench.py --smoke --sweep \
        --out BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.core.simkit.engine import Engine
from repro.core.simkit.workload import (
    bursty_requests,
    poisson_requests,
    router_summary,
    router_workload,
    serving_throughput,
    serving_workload,
)
from repro.models import get_model
from repro.serve import (
    MegaServe,
    RandomDrafter,
    Router,
    RouterConfig,
    ServeConfig,
    blocks_for,
)
from repro.serve.paged_cache import pow2_bucket
from repro.serve.server import StaticRunner, make_poisson_workload


def _step_events(srv: MegaServe) -> tuple[list, int]:
    """The decode-family step events (plain decode + spec verify) and their
    total emitted-token count — the single accounting the decode sweep and
    the spec sweep share, so the two gates can never drift on what counts as
    a decode step."""
    evs = [e for e in srv.trace_events() if e.name in ("decode", "verify")]
    return evs, sum(e.args.get("tokens", 0) for e in evs)


def _decode_stats(srv: MegaServe) -> dict:
    """Median-latency decode throughput over decode *and* spec-verify steps.

    Median step latency is robust against scheduler-noise stragglers, which
    otherwise dominate sub-ms smoke-model steps; tokens/step folds in the
    multi-token verify steps, so the rate reflects what speculation actually
    buys per unit of step latency."""
    import numpy as np

    evs, toks = _step_events(srv)
    dur = sum(e.dur for e in evs)
    med = float(np.median([e.dur for e in evs])) if evs else 0.0
    return {
        "decode_steps": len(evs),
        "decode_tokens": toks,
        "decode_s": round(dur, 4),
        "decode_ms_per_step": round(1e3 * med, 3),
        "decode_tok_s": round(
            toks / max(len(evs), 1) / max(med, 1e-9), 2
        ),
    }


def run_continuous_vs_static(cfg, params, args) -> dict:
    lens = tuple(int(x) for x in args.prompt_lens.split(","))
    specs, prompts, scfg = make_poisson_workload(
        cfg,
        n=args.requests, rate=args.rate, prompt_lens=lens,
        max_new_range=(args.max_new_lo, args.max_new_hi),
        num_slots=args.slots, block_size=args.block_size,
        num_blocks=args.num_blocks, seed=args.seed,
    )
    print(f"workload: {len(specs)} requests, rate={args.rate}/s, "
          f"prompts {min(lens)}-{max(lens)} tok, "
          f"max_new {args.max_new_lo}-{args.max_new_hi}")

    # ----------------------------------------------------------- continuous
    srv = MegaServe(cfg, params, scfg)
    # compile every decode table-width bucket up front (which widths occur
    # is timing-dependent, so no replay-based warmup covers them all), then
    # warm the prefill buckets + host path with an untimed replay
    srv.precompile()
    for s in specs:
        srv.submit(prompts[s.rid], s.max_new, arrival=s.arrival)
    srv.drain()
    srv.reset()
    for s in specs:                                   # timed replay
        srv.submit(prompts[s.rid], s.max_new, arrival=s.arrival)
    srv.drain()
    cont = srv.metrics()
    if cont["preemptions"]:
        # recompute prefills hit prompt+generated lengths the warmup never
        # saw, so their jit compiles land inside the timed window
        print(f"note: {cont['preemptions']} preemptions in the timed run — "
              "continuous tokens/s includes recompute-prefill compile time "
              "(size the pool with --num-blocks 0 for a clean comparison)")

    # --------------------------------------------------------------- static
    runner = StaticRunner(cfg, params)
    work = [(prompts[s.rid], s.max_new, s.arrival) for s in specs]
    runner.run([(p, mn, 0.0) for p, mn, _ in work], batch_size=args.slots)
    _, stat = runner.run(work, batch_size=args.slots)

    # --------------------------------------------------------------- report
    def row(name, met):
        print(f"  {name:11s} {met['generated_tokens']:6d} tok  "
              f"{met['wall_s']:7.3f} s  {met['tokens_per_s']:8.2f} tok/s  "
              f"ttft p50/p99 {met['ttft_p50_s']*1e3:7.1f}/"
              f"{met['ttft_p99_s']*1e3:7.1f} ms  "
              f"preempt {met.get('preemptions', 0)}")

    print(f"\nwall-clock ({cfg.name}, slots/batch={args.slots}, "
          f"pool {scfg.num_blocks}x{args.block_size}, "
          f"decode_path={srv.decode_path}):")
    row("static", stat)
    row("continuous", cont)
    speedup = cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9)
    print(f"  continuous/static tokens/s = {speedup:.2f}x")

    eng = Engine()
    sim_c = serving_throughput(eng.run(serving_workload(
        specs, policy="continuous", num_slots=args.slots)))
    sim_s = serving_throughput(eng.run(serving_workload(
        specs, policy="static", num_slots=args.slots, batch_size=args.slots)))
    print(f"\nsimkit offline projection: continuous {sim_c['tokens_per_s']:.0f} "
          f"tok/s vs static {sim_s['tokens_per_s']:.0f} tok/s "
          f"({sim_c['tokens_per_s']/sim_s['tokens_per_s']:.2f}x)")

    return {
        "decode_path": srv.decode_path,
        "static": stat,
        "continuous": cont,
        "speedup_tokens_per_s": round(speedup, 3),
        "simkit": {"continuous_tok_s": sim_c["tokens_per_s"],
                   "static_tok_s": sim_s["tokens_per_s"]},
        "ok": speedup > 1.0,
    }


def run_decode_sweep(cfg, params, args) -> dict:
    """Decode step latency vs pool ``max_len`` at fixed live kv_len."""
    bs = args.block_size
    plen = args.sweep_prompt_len
    max_new = args.sweep_max_new
    n = args.sweep_requests
    mean_kv = plen + max_new / 2
    import numpy as np
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(2, cfg.vocab_size, size=plen).tolist()
               for _ in range(n)]

    sweep = []
    for max_blocks in (int(x) for x in args.sweep_max_blocks.split(",")):
        max_len = max_blocks * bs
        scfg = ServeConfig(
            num_slots=args.slots, block_size=bs,
            num_blocks=args.slots * max_blocks + 1,
            max_blocks_per_slot=max_blocks,
        )
        entry = {"max_len": max_len, "max_blocks": max_blocks,
                 "mean_kv_len": mean_kv,
                 "max_len_over_mean_kv": round(max_len / mean_kv, 2)}
        for path in ("paged", "gathered"):
            srv = MegaServe(cfg, params, replace(scfg, decode_path=path))
            for p in prompts:                          # warmup
                srv.submit(p, max_new, arrival=0.0)
            srv.drain()
            srv.reset()
            for p in prompts:                          # timed
                srv.submit(p, max_new, arrival=0.0)
            srv.drain()
            entry[path] = _decode_stats(srv)
        entry["decode_speedup"] = round(
            entry["paged"]["decode_tok_s"]
            / max(entry["gathered"]["decode_tok_s"], 1e-9), 2)
        sweep.append(entry)
        print(f"  max_len {max_len:5d} ({entry['max_len_over_mean_kv']:5.1f}x "
              f"mean kv_len {mean_kv:.0f}): paged "
              f"{entry['paged']['decode_ms_per_step']:7.2f} ms/step "
              f"({entry['paged']['decode_tok_s']:8.1f} tok/s)  gathered "
              f"{entry['gathered']['decode_ms_per_step']:7.2f} ms/step "
              f"({entry['gathered']['decode_tok_s']:8.1f} tok/s)  "
              f"-> {entry['decode_speedup']:.2f}x")

    # acceptance: paged decode cost tracks live kv_len, not pool max_len —
    # at max_len/mean_kv >= 4 the paged path must hold >= 2x decode tokens/s
    gated = [e for e in sweep if e["max_len_over_mean_kv"] >= 4.0]
    ok = bool(gated) and all(e["decode_speedup"] >= 2.0 for e in gated)
    return {"slots": args.slots, "block_size": bs,
            "prompt_len": plen, "max_new": max_new, "requests": n,
            "points": sweep, "ok": ok}


def run_spec_sweep(cfg, params, args) -> dict:
    """Speculative decoding vs plain paged decode, friendly + adversarial.

    Same fixed workload three ways: plain paged decode (baseline), the
    n-gram prompt-lookup drafter (greedy smoke decode settles into repeats,
    so prompt lookup lands its drafts — the n-gram-friendly regime), and a
    deliberately-wrong ``RandomDrafter`` (acceptance ~1/V: every verify is
    wasted, bounding the worst-case regression and exercising the
    draft-length adaptation that shuts speculation off).  Greedy streams are
    asserted identical across all three runs."""
    import numpy as np

    bs, k = args.block_size, args.spec_k
    plen, max_new, n = args.spec_prompt_len, args.spec_max_new, args.spec_requests
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(2, cfg.vocab_size, size=plen).tolist()
               for _ in range(n)]
    worst = blocks_for(plen + max_new, bs)
    scfg = ServeConfig(
        num_slots=args.slots, block_size=bs,
        num_blocks=args.slots * worst + 1, max_blocks_per_slot=worst,
    )

    def est_stats(srv):
        """Deterministic decode-equivalent accounting: step counts and token
        totals are a function of the seed alone (no timing)."""
        evs, toks = _step_events(srv)
        counts: dict = {"decode": 0, "verify": 0}
        for e in evs:
            counts[e.name] += 1
        return {
            "decode_steps": len(evs),
            "decode_tokens": toks,
            "step_counts": counts,
        }

    def run(srv):
        for p in prompts:                              # warmup: compile shapes
            srv.submit(p, max_new, arrival=0.0)
        srv.drain()
        srv.reset()
        for p in prompts:                              # timed replay
            srv.submit(p, max_new, arrival=0.0)
        outs = srv.drain()
        return outs, {**srv.metrics(), **est_stats(srv)}

    def measure_cost_ratio() -> float:
        """min-of-N *interleaved* timing of the compiled plain-decode vs
        spec-verify steps at the workload's mean kv_len.

        The serving runs themselves are hostage to shared-box noise (their
        sub-ms steps drift 30%+ between runs), so the gate combines the
        *deterministic* step/token counts from the runs with this directly
        measured cost ratio: interleaving the two executables makes box
        drift hit both numerators equally, and min-of-N discards scheduler
        stragglers."""
        import time

        import jax
        import jax.numpy as jnp

        srv = MegaServe(
            cfg, params, replace(scfg, spec_decode=True, spec_k=k))
        probe_len = min(plen + max_new // 2, scfg.max_len - k - 2)
        for _ in range(scfg.num_slots):
            srv.submit(rng.integers(2, cfg.vocab_size, size=probe_len).tolist(),
                       4, arrival=0.0)
        for _ in range(scfg.num_slots + 1):            # admit + a few decodes
            srv.step()
        active = srv.sched.active_slots()
        tables = srv._live_tables(active)
        pos = jnp.asarray(srv.sched.pos, jnp.int32)
        toks1 = jnp.asarray(srv.sched.last_tok, jnp.int32)
        toksq = jnp.zeros((scfg.num_slots, k + 1), jnp.int32)
        pool = srv.pool

        def t_decode():
            nonlocal pool
            t0 = time.perf_counter()
            pool, tok, _ = srv._decode(params, pool, tables, toks1, pos)
            jax.block_until_ready(tok)
            return time.perf_counter() - t0

        def t_verify():
            nonlocal pool
            t0 = time.perf_counter()
            pool, g, _, _ = srv._spec_step(params, pool, tables, toksq, pos)
            jax.block_until_ready(g)
            return time.perf_counter() - t0

        t_decode(), t_verify()                         # compile/warm both
        best_d = best_v = 9e9
        for _ in range(60):
            best_d = min(best_d, t_decode())
            best_v = min(best_v, t_verify())
        return best_v / max(best_d, 1e-9)

    def dec_equiv_rate(met, cost: float):
        """Tokens per decode-equivalent step: verify steps are charged at
        the measured verify/decode cost ratio."""
        steps = met["step_counts"]["decode"] + met["step_counts"]["verify"] * cost
        return met["decode_tokens"] / max(steps, 1e-9)

    cost = measure_cost_ratio()
    print(f"  measured verify/decode step-cost ratio: {cost:.2f}x "
          f"(Q={k + 1}, interleaved min-of-60)")
    base_outs, base = run(MegaServe(cfg, params, scfg))
    base_rate = dec_equiv_rate(base, cost)
    result = {
        "slots": args.slots, "block_size": bs, "spec_k": k,
        "prompt_len": plen, "max_new": max_new, "requests": n,
        "baseline": {"tokens_per_s": base["tokens_per_s"],
                     "tokens_per_dec_step": round(base_rate, 3),
                     "steps": base["steps"]},
        "verify_cost_vs_decode": round(cost, 3),
    }
    modes = {
        "ngram": None,                                  # default drafter
        "adversarial": RandomDrafter(cfg.vocab_size, seed=args.seed),
    }
    for name, drafter in modes.items():
        srv = MegaServe(
            cfg, params, replace(scfg, spec_decode=True, spec_k=k),
            drafter=drafter,
        )
        outs, met = run(srv)
        assert outs == base_outs, f"{name}: speculative streams diverged"
        # gate on tokens per decode-equivalent step (wall-clock tokens/s is
        # reported too but is hostage to scheduler noise on shared boxes)
        rate = dec_equiv_rate(met, cost)
        speedup = rate / max(base_rate, 1e-9)
        result[name] = {
            "tokens_per_s": met["tokens_per_s"],
            "tokens_per_dec_step": round(rate, 3),
            "tokens_per_step": round(
                met["decode_tokens"] / max(met["decode_steps"], 1), 3),
            "steps": met["steps"],
            "accept_rate": round(met["spec_accept_rate"], 4),
            "speedup_vs_baseline": round(speedup, 3),
        }
        print(f"  {name:12s} {rate:6.2f} tok/dec-step "
              f"(baseline {base_rate:5.2f})  "
              f"accept {met['spec_accept_rate']:.2f}  "
              f"steps {met['steps']:4d} vs {base['steps']:4d}  "
              f"-> {speedup:.2f}x")
    # acceptance: speculation must pay on friendly workloads and cost little
    # on hostile ones (adaptation shuts it off)
    ok = (result["ngram"]["speedup_vs_baseline"] >= 1.3
          and result["adversarial"]["speedup_vs_baseline"] >= 0.9)
    result["ok"] = bool(ok)
    return result


def run_prefill_sweep(cfg, params, args) -> dict:
    """Prefill cost vs prompt length: flash vs dense, gated on op-count
    accounting with measured ref-path parity.

    The dense path prefills a padded B=1 cache — a full ``bucket x bucket``
    causally-masked score matrix — then pays a pool-sized gather/scatter
    round trip (``scatter_prefill``).  The flash path scatters K/V straight
    into the slot's blocks and computes only the block-granular causal band
    (~``bucket²/2`` score positions), with no dense-cache copy; chunked
    flash prefill goes further — each chunk's table is pow2-bucketed to the
    *live* offset, so its cost tracks kv_len rather than the slot's bucket
    ceiling.

    **Why op-count gates**: on CPU both paths run jnp oracles (the Pallas
    kernel is TPU-only; interpret mode is a correctness harness, not a perf
    path), and the banded oracle's per-band gathers make its *wall clock* a
    poor proxy for the kernel's DMA-pipelined table walk.  So this sweep (a)
    asserts measured ref-path parity — flash and dense serve token-identical
    streams on every point — and (b) gates on the deterministic score-op
    accounting of what each path computes, exactly as implemented
    (block-granular bands, pow2 table widths).  Wall-clock ms/token is
    reported alongside for the record.

    Gates: dense/flash op ratio >= 1.5x at prompts >= ``--prefill-gate-len``;
    chunked-flash op cost for an off-bucket prompt (3/4 of the bucket) <=
    0.8x the full-bucket prompt's, while one-shot dense pays the identical
    bucket cost for both (the "tracks kv_len, not bucket ceiling" gate).
    """
    import numpy as np

    bs = args.block_size
    qb = 32                                   # server flash q_block
    lens = sorted(int(x) for x in args.prefill_lens.split(","))
    L = lens[-1]
    # off-bucket pair: both land in the same pow2 bucket (e.g. 384 and 496
    # both pad to 512), isolating band length from bucket length
    pair = ((3 * L) // 4, L - bs)
    all_lens = sorted(set(lens + list(pair)))
    worst = pow2_bucket(blocks_for(max(all_lens) + 1, bs))
    scfg = ServeConfig(
        num_slots=2, block_size=bs, num_blocks=2 * worst + 1,
        max_blocks_per_slot=worst,
    )
    rng = np.random.default_rng(args.seed)
    reps = args.prefill_repeats

    def bucket_len(P: int) -> int:
        # +1: the pool must also hold the prefill's first generated token
        return min(pow2_bucket(blocks_for(P + 1, bs)), worst) * bs

    def flash_ops(P: int) -> int:
        # block-granular causal band over the padded bucket (q_start=0):
        # q-block [qlo, qlo+qb) attends ceil(min(kvl, qlo+qb)/bs) blocks
        B = bucket_len(P)
        return sum(
            min(qb, B - qlo) * (-(-min(B, qlo + qb) // bs) * bs)
            for qlo in range(0, B, qb)
        )

    def dense_ops(P: int) -> int:
        B = bucket_len(P)
        return B * B

    def chunk_ops(P: int, C: int) -> int:
        # chunked flash: the chunk at offset w walks a table pow2-bucketed
        # to blocks_for(w + C) — cost tracks the live kv_len, not the slot
        return sum(
            C * min(pow2_bucket(blocks_for(w + C, bs)), worst) * bs
            for w in range(0, P, C)
        )

    prompts_for = {
        P: [rng.integers(2, cfg.vocab_size, size=P).tolist()
            for _ in range(reps)]
        for P in all_lens
    }

    def measure(ppath: str, P: int, **kw) -> dict:
        srv = MegaServe(cfg, params, replace(scfg, prefill_path=ppath, **kw))
        prompts = prompts_for[P]
        for p in prompts:                          # warmup: compile the bucket
            srv.submit(p, 1, arrival=0.0)
        srv.drain()
        srv.reset()
        for p in prompts:                          # timed replay
            srv.submit(p, 1, arrival=0.0)
        outs = srv.drain()
        durs = [e.dur for e in srv.trace_events()
                if e.name in ("prefill", "prefill_chunk")]
        if kw.get("chunked_prefill"):
            # chunked: one prompt = many chunk events; charge the mean total
            best = sum(durs) / reps
        else:
            assert len(durs) == reps
            best = min(durs)                       # min-of-N: drop stragglers
        return {"ms": round(1e3 * best, 3),
                "ms_per_token": round(1e3 * best / P, 5)}, outs

    points = []
    for P in all_lens:
        flash, f_outs = measure("flash", P)
        dense, d_outs = measure("dense", P)
        assert f_outs == d_outs, f"P={P}: flash/dense streams diverged"
        fo, do = flash_ops(P), dense_ops(P)
        entry = {"prompt_len": P, "bucket_len": bucket_len(P),
                 "flash": flash, "dense": dense,
                 "flash_score_ops": fo, "dense_score_ops": do,
                 "op_speedup": round(do / fo, 2),
                 "measured_speedup": round(
                     dense["ms_per_token"]
                     / max(flash["ms_per_token"], 1e-9), 2)}
        points.append(entry)
        print(f"  prompt {P:5d} (bucket {entry['bucket_len']:5d}): "
              f"flash {flash['ms_per_token']:7.4f} ms/tok "
              f"({fo:9d} ops)  dense {dense['ms_per_token']:7.4f} ms/tok "
              f"({do:9d} ops)  -> {entry['op_speedup']:.2f}x ops, "
              f"{entry['measured_speedup']:.2f}x measured, parity OK")

    gate_len = args.prefill_gate_len
    gated = [e for e in points if e["prompt_len"] >= gate_len]
    speed_ok = bool(gated) and all(e["op_speedup"] >= 1.5 for e in gated)

    # kv_len tracking through the chunked entry shape of the same kernel:
    # same bucket, shorter prompt -> proportionally less chunked-flash work,
    # while the one-shot dense cost is pinned to the bucket
    C = 4 * bs
    off, full = pair
    track = {}
    for P in pair:
        m, _ = measure("flash", P, chunked_prefill=True, chunk_len=C)
        track[P] = {"measured_ms": m["ms"], "ops": chunk_ops(P, C)}
    op_ratio = track[off]["ops"] / track[full]["ops"]
    ms_ratio = (track[off]["measured_ms"]
                / max(track[full]["measured_ms"], 1e-9))
    dense_ratio = dense_ops(off) / dense_ops(full)
    track_ok = op_ratio <= 0.8
    print(f"  kv_len tracking (chunked flash, chunk={C}): "
          f"{off}/{full} op ratio {op_ratio:.2f} "
          f"(measured {ms_ratio:.2f}; one-shot dense {dense_ratio:.2f}, "
          "bucket-bound)")
    return {
        "block_size": bs, "q_block": qb, "repeats": reps,
        "gate_len": gate_len, "points": points,
        "kv_len_tracking": {
            "chunk_len": C, "pair": list(pair),
            "chunked_flash_op_ratio": round(op_ratio, 3),
            "chunked_flash_measured_ratio": round(ms_ratio, 3),
            "dense_op_ratio": round(dense_ratio, 3),
        },
        "ok": bool(speed_ok and track_ok),
    }


def run_coldstart(cfg, params, args) -> dict:
    """Cold vs warm start-to-first-token through the persistent compile
    cache.

    Both runs build a fresh engine, precompile the full bucket ladder, and
    serve one request; the cold run populates an empty ``CompileCache``
    directory, the warm run (a fresh engine + cache instance against the
    same directory — the in-process stand-in for a restarted replica, with
    true cross-process reuse asserted in ``tests/test_compile_cache.py``)
    must deserialize every bucket (0 misses) and cut start-to-first-token
    by >= 2x.  Greedy first tokens must be identical."""
    import shutil
    import tempfile

    import numpy as np

    from repro.core.compile_cache import CompileCache

    bs = args.block_size
    worst = pow2_bucket(blocks_for(64 + 4, bs))
    scfg = ServeConfig(
        num_slots=2, block_size=bs, num_blocks=2 * worst + 1,
        max_blocks_per_slot=worst, chunked_prefill=True,
    )
    rng = np.random.default_rng(args.seed)
    prompt = rng.integers(2, cfg.vocab_size, size=40).tolist()
    root = tempfile.mkdtemp(prefix="serve_bench_cc_")

    def start_to_first_token(cache):
        t0 = time.perf_counter()
        srv = MegaServe(cfg, params, scfg, compile_cache=cache)
        rep = srv.precompile()
        srv.submit(prompt, 4, arrival=0.0)
        while not any(srv.streams.values()):
            srv.step()
        dt = time.perf_counter() - t0
        first = srv.streams[0][0].token
        srv.drain()
        return dt, first, rep

    try:
        t_cold, tok_cold, rep_cold = start_to_first_token(CompileCache(root))
        t_warm, tok_warm, rep_warm = start_to_first_token(CompileCache(root))
        t_none, tok_none, _ = start_to_first_token(None)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    assert tok_cold == tok_warm == tok_none, "cache changed the stream"
    assert rep_warm["cache"]["misses"] == 0, rep_warm["cache"]
    assert rep_warm["cache"]["hits"] == rep_cold["cache"]["puts"]
    speedup = t_cold / max(t_warm, 1e-9)
    print(f"  start-to-first-token: cold {t_cold:6.2f} s "
          f"({rep_cold['cache']['puts']} executables compiled+persisted)  "
          f"warm {t_warm:6.2f} s ({rep_warm['cache']['hits']} cache hits)  "
          f"-> {speedup:.1f}x")
    return {
        "cold_s": round(t_cold, 3), "warm_s": round(t_warm, 3),
        "nocache_s": round(t_none, 3),
        "speedup": round(speedup, 2),
        "executables": rep_cold["cache"]["puts"],
        "warm_hits": rep_warm["cache"]["hits"],
        "precompile_ms_cold": {
            p: rep_cold[p]["ms"] for p in ("decode", "prefill", "chunk")},
        "precompile_ms_warm": {
            p: rep_warm[p]["ms"] for p in ("decode", "prefill", "chunk")},
        "ok": bool(speedup >= 2.0),
    }


def run_router_sweep(cfg, params, args) -> dict:
    """MegaRoute policy sweep with one degraded replica.

    The regime where placement *matters* (and the paper's straggler theme):
    symmetric deterministic replicas make round-robin near-optimal — count
    balance is work balance — so the sweep degrades replica 1 to 1/3 speed
    via ``replica_step_every=[1, 3]`` (the straggler is stepped every 3rd
    router tick).  In-process replicas step in lockstep, so sleeping inside
    a replica's jitted step slows *every* replica's tick equally and leaves
    per-tick throughput symmetric — step thinning is the honest
    single-process straggler, and it matches the offline model's
    ``replica_speeds`` semantics exactly.  Round-robin keeps feeding the
    straggler; queue-aware policies divert.  Each (policy, traffic) cell
    replays the same arrival trace through a 2-replica router; the gate
    demands a load-aware policy beat round_robin by >= 1.2x on p99 TTFT
    under bursty traffic, with the offline simkit evaluation (same speeds)
    agreeing on the winner's rank vs round_robin."""
    import numpy as np

    lens, new_rng = (16, 32, 256), (4, 48)
    n, rate, seed = args.router_requests, args.router_rate, args.seed
    worst = blocks_for(max(lens) + new_rng[1], args.block_size)
    scfg = ServeConfig(
        num_slots=args.slots, block_size=args.block_size,
        num_blocks=args.slots * worst + 1, max_blocks_per_slot=worst,
    )
    rng = np.random.default_rng(seed)
    traces = {
        "poisson": poisson_requests(
            n, rate, prompt_lens=lens, max_new_range=new_rng, seed=seed),
        "bursty": bursty_requests(
            n, rate, burst_mult=10.0, burst_frac=0.2, burst_dwell_s=0.3,
            prompt_lens=lens, max_new_range=new_rng, seed=seed),
    }
    prompts = {
        t: {s.rid: rng.integers(2, cfg.vocab_size, size=s.prompt_len).tolist()
            for s in specs}
        for t, specs in traces.items()
    }

    # the straggler: replica 1 is stepped every 3rd router tick -> uniform
    # 1/3 speed across prefill AND decode (see the docstring for why a
    # sleep inside the replica's steps cannot model this in one process)
    step_every = args.router_step_every
    speed_slow = 1.0 / step_every
    print(f"  degrading replica 1: stepped every {step_every} router ticks "
          f"(relative speed {speed_slow:.2f}, prefill and decode alike)")

    policies = ("round_robin", "least_kv", "jsq")
    cells: dict = {t: {} for t in traces}
    for traffic, specs in traces.items():
        for policy in policies:
            router = Router(
                cfg, params, scfg, RouterConfig(replicas=2, policy=policy),
                replica_step_every=[1, step_every],
            )
            # compile all decode widths up front, then warm prefill buckets
            # + the host path by replaying the exact timed trace (any compile
            # landing inside the timed window would swamp the policy signal)
            router.precompile()
            for s in specs:
                router.submit(prompts[traffic][s.rid], s.max_new,
                              arrival=s.arrival)
            router.drain()
            router.reset()
            for s in specs:                            # timed replay
                router.submit(prompts[traffic][s.rid], s.max_new,
                              arrival=s.arrival)
            router.drain()
            met = router.metrics()
            cells[traffic][policy] = {
                "ttft_p50_s": met["ttft_p50_s"],
                "ttft_p99_s": met["ttft_p99_s"],
                "latency_p50_s": met["latency_p50_s"],
                "latency_p99_s": met["latency_p99_s"],
                "tokens_per_s": met["tokens_per_s"],
                "shed_rate": met["shed_rate"],
                "preemptions": met["preemptions"],
                "placed_per_replica": met["placed_per_replica"],
                "replica_tokens": met["replica_tokens"],
                "load_skew": round(met["load_skew"], 3),
            }
            print(f"  {traffic:8s} {policy:12s} ttft p50/p99 "
                  f"{met['ttft_p50_s'] * 1e3:7.1f}/"
                  f"{met['ttft_p99_s'] * 1e3:7.1f} ms  "
                  f"placed {met['placed_per_replica']}  "
                  f"skew {met['load_skew']:.2f}")

    # offline evaluation of the bursty scenario at the calibrated speeds:
    # the simkit ranking must agree with the live winner's rank vs RR
    offline: dict = {}
    for policy in policies:
        tasks = router_workload(
            traces["bursty"], policy=policy, n_replicas=2,
            num_slots=args.slots,
            kv_capacity_tokens=scfg.usable_blocks * scfg.block_size,
            replica_speeds=(1.0, speed_slow),
        )
        offline[policy] = router_summary(
            Engine().run(tasks), n_replicas=2)["ttft_p99_s"]

    bursty = cells["bursty"]
    rr99 = bursty["round_robin"]["ttft_p99_s"]
    ratios = {p: rr99 / max(bursty[p]["ttft_p99_s"], 1e-9)
              for p in ("least_kv", "jsq")}
    winner = min(bursty, key=lambda p: bursty[p]["ttft_p99_s"])
    best_ratio = max(ratios.values())
    ranking_agrees = (
        winner != "round_robin"
        and offline[winner] < offline["round_robin"]
    )
    print(f"  bursty p99-TTFT gain vs round_robin: "
          + ", ".join(f"{p} {r:.2f}x" for p, r in ratios.items())
          + f"  (online winner: {winner}; offline p99 "
          + ", ".join(f"{p} {v * 1e3:.0f}ms" for p, v in offline.items())
          + ")")
    ok = best_ratio >= 1.2 and ranking_agrees
    return {
        "requests": n, "rate": rate, "slots": args.slots,
        "prompt_lens": list(lens), "max_new_range": list(new_rng),
        "degraded_replica": {"index": 1, "step_every": step_every,
                             "relative_speed": round(speed_slow, 3)},
        "cells": cells,
        "offline_bursty_ttft_p99_s": {
            p: round(v, 5) for p, v in offline.items()},
        "bursty_gain_vs_round_robin": {
            p: round(r, 3) for p, r in ratios.items()},
        "online_winner": winner,
        "ranking_agrees": bool(ranking_agrees),
        "ok": bool(ok),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=150.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous slots == static batch size")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="physical KV blocks (0 = size for zero preemption)")
    ap.add_argument("--prompt-lens", default="16,32,64,128,256")
    ap.add_argument("--max-new-lo", type=int, default=4)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweep", action="store_true",
                    help="decode-latency-vs-max_len paged/gathered sweep "
                         "+ speculative-decoding sweep")
    ap.add_argument("--sweep-max-blocks", default="4,16,64",
                    help="pool max_blocks_per_slot values to sweep")
    ap.add_argument("--sweep-prompt-len", type=int, default=16)
    ap.add_argument("--sweep-max-new", type=int, default=24)
    ap.add_argument("--sweep-requests", type=int, default=12)
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max draft tokens per verify step (spec sweep)")
    ap.add_argument("--spec-prompt-len", type=int, default=16)
    ap.add_argument("--spec-max-new", type=int, default=192)
    ap.add_argument("--spec-requests", type=int, default=6)
    ap.add_argument("--prefill-sweep", action="store_true",
                    help="prefill ms/token vs prompt length, flash vs dense "
                         "+ kv_len-vs-bucket tracking gate")
    ap.add_argument("--prefill-lens", default="64,128,256,512",
                    help="prompt lengths for --prefill-sweep")
    ap.add_argument("--prefill-gate-len", type=int, default=512,
                    help="gate flash >= 1.5x dense at prompts >= this")
    ap.add_argument("--prefill-repeats", type=int, default=6,
                    help="timed prefills per (path, length) cell (min-of-N)")
    ap.add_argument("--coldstart", action="store_true",
                    help="cold-vs-warm start-to-first-token through the "
                         "persistent compile cache")
    ap.add_argument("--router-sweep", action="store_true",
                    help="MegaRoute placement-policy sweep (poisson + bursty "
                         "traffic, one degraded replica)")
    ap.add_argument("--router-requests", type=int, default=120)
    ap.add_argument("--router-rate", type=float, default=40.0)
    ap.add_argument("--router-step-every", type=int, default=4,
                    help="straggler replica is stepped every N router ticks")
    ap.add_argument("--out", default="",
                    help="write results JSON (e.g. BENCH_serve.json)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.input_kind != "tokens":
        raise SystemExit(f"{cfg.name}: serve token archs")
    m = get_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))

    results: dict = {"arch": cfg.name, "smoke": args.smoke,
                     "backend": jax.default_backend()}
    ok = True
    if args.sweep:
        print(f"decode-latency sweep ({cfg.name}, slots={args.slots}, "
              f"block_size={args.block_size}):")
        results["decode_sweep"] = run_decode_sweep(cfg, params, args)
        ok &= results["decode_sweep"]["ok"]
        if not results["decode_sweep"]["ok"]:
            print("FAIL: paged decode did not hold >=2x tokens/s at "
                  "max_len/mean_kv_len >= 4")
        print()
        if not cfg.use_mla and cfg.family in ("dense", "moe"):
            print(f"speculative-decoding sweep ({cfg.name}, "
                  f"slots={args.slots}, spec_k={args.spec_k}):")
            results["spec_sweep"] = run_spec_sweep(cfg, params, args)
            ok &= results["spec_sweep"]["ok"]
            if not results["spec_sweep"]["ok"]:
                print("FAIL: spec decode below 1.3x on the n-gram-friendly "
                      "workload or below 0.9x on the adversarial one")
            print()
    if args.prefill_sweep:
        print(f"prefill-latency sweep ({cfg.name}, "
              f"block_size={args.block_size}):")
        results["prefill_sweep"] = run_prefill_sweep(cfg, params, args)
        ok &= results["prefill_sweep"]["ok"]
        if not results["prefill_sweep"]["ok"]:
            print("FAIL: flash prefill below 1.5x dense ms/token at prompt "
                  f">= {args.prefill_gate_len}, or its cost tracked the "
                  "bucket ceiling instead of kv_len")
        print()
    if args.coldstart:
        print(f"cold-vs-warm start-to-first-token ({cfg.name}, "
              "persistent compile cache):")
        results["coldstart"] = run_coldstart(cfg, params, args)
        ok &= results["coldstart"]["ok"]
        if not results["coldstart"]["ok"]:
            print("FAIL: warm compile cache did not cut start-to-first-token "
                  ">= 2x")
        print()
    if args.router_sweep:
        print(f"router policy sweep ({cfg.name}, 2 replicas x "
              f"{args.slots} slots, one degraded):")
        results["router"] = run_router_sweep(cfg, params, args)
        ok &= results["router"]["ok"]
        if not results["router"]["ok"]:
            print("FAIL: no load-aware policy beat round_robin >=1.2x on "
                  "bursty p99 TTFT with the offline ranking agreeing")
        print()
    results["continuous_vs_static"] = run_continuous_vs_static(cfg, params, args)
    ok &= results["continuous_vs_static"]["ok"]
    if not results["continuous_vs_static"]["ok"]:
        print("FAIL: continuous batching did not beat static batching")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    if not ok:
        sys.exit(1)
    print("OK")


if __name__ == "__main__":
    main()
