"""Static vs continuous batching on a mixed-length Poisson-arrival workload.

Both engines run the same model, same requests, same arrival process; each is
warmed up (all shapes compiled) on an arrival-at-zero copy of the workload,
then timed on a fresh replay with real arrival gaps.  Also reports the
offline simkit projection of the same trace for cross-checking policy wins
against the wall-clock run.

    PYTHONPATH=src python benchmarks/serve_bench.py --arch qwen2-0.5b --smoke \
        --requests 24 --rate 150 --slots 4
"""

from __future__ import annotations

import argparse
import sys

import jax

from repro.configs import get_config
from repro.core.simkit.engine import Engine
from repro.core.simkit.workload import serving_throughput, serving_workload
from repro.models import get_model
from repro.serve import MegaServe
from repro.serve.server import StaticRunner, make_poisson_workload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=150.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous slots == static batch size")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="physical KV blocks (0 = size for zero preemption)")
    ap.add_argument("--prompt-lens", default="16,32,64,128,256")
    ap.add_argument("--max-new-lo", type=int, default=4)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.input_kind != "tokens":
        raise SystemExit(f"{cfg.name}: serve token archs")
    m = get_model(cfg)
    params = m.init(cfg, jax.random.PRNGKey(0))

    lens = tuple(int(x) for x in args.prompt_lens.split(","))
    specs, prompts, scfg = make_poisson_workload(
        cfg,
        n=args.requests, rate=args.rate, prompt_lens=lens,
        max_new_range=(args.max_new_lo, args.max_new_hi),
        num_slots=args.slots, block_size=args.block_size,
        num_blocks=args.num_blocks, seed=args.seed,
    )
    print(f"workload: {len(specs)} requests, rate={args.rate}/s, "
          f"prompts {min(lens)}-{max(lens)} tok, "
          f"max_new {args.max_new_lo}-{args.max_new_hi}")

    # ----------------------------------------------------------- continuous
    bs = args.block_size
    srv = MegaServe(cfg, params, scfg)
    for s in specs:                                   # warmup: compile shapes
        srv.submit(prompts[s.rid], s.max_new, arrival=0.0)
    srv.drain()
    srv.reset()
    for s in specs:                                   # timed replay
        srv.submit(prompts[s.rid], s.max_new, arrival=s.arrival)
    srv.drain()
    cont = srv.metrics()
    if cont["preemptions"]:
        # recompute prefills hit prompt+generated lengths the warmup never
        # saw, so their jit compiles land inside the timed window
        print(f"note: {cont['preemptions']} preemptions in the timed run — "
              "continuous tokens/s includes recompute-prefill compile time "
              "(size the pool with --num-blocks 0 for a clean comparison)")

    # --------------------------------------------------------------- static
    runner = StaticRunner(cfg, params)
    work = [(prompts[s.rid], s.max_new, s.arrival) for s in specs]
    runner.run([(p, mn, 0.0) for p, mn, _ in work], batch_size=args.slots)
    _, stat = runner.run(work, batch_size=args.slots)

    # --------------------------------------------------------------- report
    def row(name, met):
        print(f"  {name:11s} {met['generated_tokens']:6d} tok  "
              f"{met['wall_s']:7.3f} s  {met['tokens_per_s']:8.2f} tok/s  "
              f"ttft p50/p99 {met['ttft_p50_s']*1e3:7.1f}/"
              f"{met['ttft_p99_s']*1e3:7.1f} ms  "
              f"preempt {met.get('preemptions', 0)}")

    print(f"\nwall-clock ({cfg.name}, slots/batch={args.slots}, "
          f"pool {scfg.num_blocks}x{bs}):")
    row("static", stat)
    row("continuous", cont)
    speedup = cont["tokens_per_s"] / max(stat["tokens_per_s"], 1e-9)
    print(f"  continuous/static tokens/s = {speedup:.2f}x")

    eng = Engine()
    sim_c = serving_throughput(eng.run(serving_workload(
        specs, policy="continuous", num_slots=args.slots)))
    sim_s = serving_throughput(eng.run(serving_workload(
        specs, policy="static", num_slots=args.slots, batch_size=args.slots)))
    print(f"\nsimkit offline projection: continuous {sim_c['tokens_per_s']:.0f} "
          f"tok/s vs static {sim_s['tokens_per_s']:.0f} tok/s "
          f"({sim_c['tokens_per_s']/sim_s['tokens_per_s']:.2f}x)")

    if speedup <= 1.0:
        print("FAIL: continuous batching did not beat static batching")
        sys.exit(1)
    print("OK")


if __name__ == "__main__":
    main()
