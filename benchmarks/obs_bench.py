"""Observability overhead gate: instrumented vs bare train step.

Two arms of the same CPU-smoke training run, timed end-to-end per step
(the timer plugin's ``wrap_step`` blocks on the loss, so consecutive-entry
diffs include everything the loop does between steps — metrics
publication, per-rank event synthesis, the OnlineDetector's sliding-window
passes, and trace streaming):

* **bare** — no module plugins at all: tracer disabled, no registry;
* **instrumented** — the full observability stack: ``scan`` (tracing +
  ``detect_online`` with per-rank event synthesis) + ``metrics`` (registry
  sampling and counter events) streaming to a ``--trace-out`` sidecar.

Arms alternate across ``--repeats`` runs and each arm scores its
minimum-of-medians — the floor is the arm's true cost; the spikes are
background noise (this runs on shared, sometimes single-core CI hosts).
The gate asserts the instrumented floor stays within ``--max-overhead``
(default 5%) of bare, and persists both trajectories to
``BENCH_obs.json``.

    PYTHONPATH=src python benchmarks/obs_bench.py --out BENCH_obs.json
    make bench-obs
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.app.config import build_run_config
from repro.app.plugins import ModulePlugin, build_plugins
from repro.app.session import Session

WARMUP = 4  # dropped from each arm: compile + cache-settling steps


class _StepTimer(ModulePlugin):
    """Records a wall-clock entry as each step's results land on host."""

    name = "bench-timer"

    def __init__(self, run_cfg):
        super().__init__(run_cfg)
        self.entries: list[float] = []

    def wrap_step(self, step_fn):
        def timed(state, batch):
            out = step_fn(state, batch)
            jax.block_until_ready(out[1]["loss"])
            self.entries.append(time.perf_counter())
            return out

        return timed


def _arm(instrumented: bool, *, arch: str, steps: int, workdir: Path) -> dict:
    # seq 128 keeps the smoke step big enough (~20ms on CPU) that the
    # fixed per-step observability cost is measured as a ratio against a
    # meaningful denominator — on real steps (seconds) it vanishes
    sets = [
        f"train.steps={steps}", "train.seq_len=128", "train.global_batch=4",
        f"train.log_every={steps}",
    ]
    if instrumented:
        sets += [
            "scan.detect_online=true", "scan.detect_every=4",
            "obs.rank_events=true", "obs.dp=2",
            f"obs.metrics_out={workdir / 'metrics.jsonl'}",
        ]
    cfg = build_run_config(
        "train", arch=arch, smoke=True, sets=sets,
        trace_out=str(workdir / "trace.jsonl") if instrumented else "",
    )
    timer = _StepTimer(cfg)
    plugins = (
        build_plugins(("scan", "metrics"), cfg) + [timer]
        if instrumented else [timer]
    )
    session = Session(cfg, plugins=plugins)
    session.run()

    deltas = np.diff(timer.entries)
    steady = deltas[WARMUP:] if len(deltas) > 2 * WARMUP else deltas
    out = {
        "steps_timed": len(steady),
        "step_ms_median": round(float(np.median(steady)) * 1e3, 3),
        "step_ms_mean": round(float(np.mean(steady)) * 1e3, 3),
        "step_ms_p95": round(float(np.quantile(steady, 0.95)) * 1e3, 3),
    }
    if instrumented:
        online = session.results.get("scan", {}).get("online", {})
        out["detect_passes"] = online.get("passes", 0)
        out["metrics_rows"] = session.results.get("metrics", {}).get("rows", 0)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--repeats", type=int, default=3,
                    help="alternating runs per arm; each arm scores its "
                         "min-of-medians (robust to background noise)")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="gate: instrumented/bare - 1 must stay below this")
    ap.add_argument("--out", default="", help="write BENCH_obs.json")
    args = ap.parse_args()

    arms: dict[bool, list[dict]] = {False: [], True: []}
    with tempfile.TemporaryDirectory() as td:
        workdir = Path(td)
        for rep in range(args.repeats):
            for instrumented in (False, True):
                arms[instrumented].append(_arm(
                    instrumented, arch=args.arch, steps=args.steps,
                    workdir=workdir,
                ))
                r = arms[instrumented][-1]
                print(f"  rep {rep} {'inst' if instrumented else 'bare'}: "
                      f"{r['step_ms_median']:.2f} ms/step")

    bare = min(arms[False], key=lambda r: r["step_ms_median"])
    inst = min(arms[True], key=lambda r: r["step_ms_median"])
    overhead = inst["step_ms_median"] / bare["step_ms_median"] - 1.0
    ok = overhead < args.max_overhead
    print(f"bare         : {bare['step_ms_median']:.2f} ms/step "
          f"(min of {args.repeats} medians, {bare['steps_timed']} steps)")
    print(f"instrumented : {inst['step_ms_median']:.2f} ms/step "
          f"({inst['detect_passes']} online detect passes, "
          f"{inst['metrics_rows']} metric rows)")
    print(f"overhead     : {overhead * 100:+.2f}% "
          f"(gate < {args.max_overhead * 100:.0f}%) "
          f"{'OK' if ok else 'FAIL'}")

    results = {
        "arch": args.arch,
        "steps": args.steps,
        "repeats": args.repeats,
        "bare": bare,
        "instrumented": inst,
        "bare_medians_ms": [r["step_ms_median"] for r in arms[False]],
        "instrumented_medians_ms": [r["step_ms_median"] for r in arms[True]],
        "overhead_frac": round(overhead, 4),
        "max_overhead": args.max_overhead,
        "ok": bool(ok),
        "backend": jax.default_backend(),
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    if not ok:
        raise SystemExit(
            f"observability overhead {overhead * 100:.2f}% exceeds the "
            f"{args.max_overhead * 100:.0f}% gate"
        )


if __name__ == "__main__":
    main()
