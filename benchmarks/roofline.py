"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, derives the three roofline terms from the
compiled HLO (cost analysis + SPMD-dump collective accounting, scan-corrected
via depth probes — see launch/dryrun.py):

  compute    = HLO_FLOPs_per_device / 197 TFLOP/s          (bf16 MXU peak)
  memory     = HLO_bytes_per_device / 819 GB/s             (HBM)
  collective = collective_bytes_per_device / 50 GB/s       (ICI link)

plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (fwd-only), the
useful-compute ratio, the dominant term, and the roofline fraction
(useful-compute time / bottleneck-term time — the MFU analogue).

Caveats recorded in EXPERIMENTS.md: XLA:CPU float-normalization inflates
bf16 buffer traffic ~2x in `memory` (upper bound); `collective` uses the
TPU-adjusted volume (grad all-reduces counted at reduce-scatter cost).

Usage:
    PYTHONPATH=src python -m benchmarks.roofline --dir artifacts/dryrun [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s
LINK_BW = 50e9          # bytes/s per ICI link


def analyze_cell(art: dict) -> dict:
    corr = art.get("corrected") or {}
    flops = corr.get("flops", art["flops_per_device"])
    bytes_acc = corr.get("bytes_accessed", art["bytes_accessed_per_device"])
    coll = corr.get(
        "collective_bytes_tpu",
        corr.get("collective_bytes", art["collectives"]["total_bytes"]),
    )
    devices = art["devices"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    model_flops = art.get("model_flops", 0.0)
    if art["kind"] == "prefill":
        # prefill computes logits only at the last position: exclude the
        # unembed matmul from the useful-FLOPs model
        from repro.configs import get_config

        cfg = get_config(art["arch"])
        model_flops -= 2 * cfg.padded_vocab * cfg.d_model * art["tokens"]
    useful_t = model_flops / devices / PEAK_FLOPS
    bound_t = max(terms.values())
    frac = useful_t / bound_t if bound_t > 0 else 0.0
    ratio = model_flops / (flops * devices) if flops else 0.0
    return {
        "arch": art["arch"],
        "shape": art["shape"],
        "mesh": "2x16x16" if art["multi_pod"] else "16x16",
        "kind": art["kind"],
        "devices": devices,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": ratio,
        "roofline_frac": frac,
        "peak_mem_gib": art["memory"]["peak_est_bytes"] / 2**30,
        "collective_counts": art["collectives"].get("counts", {}),
    }


ACTIONS = {
    ("compute", True): "cut remat recompute (save attention outs / mlp acts selectively)",
    ("compute", False): "reduce redundant per-device compute (replicated-head fallback, CE chunk recompute)",
    ("memory", True): "larger fused blocks / fewer materialized intermediates (bf16 everywhere, fused kernels)",
    ("memory", False): "keep weights resident (TP) and shrink cache reads (windowing, MLA latents)",
    ("collective", True): "shrink FSDP gather volume: group layers per gather, or shift FSDP->TP for hot dims",
    ("collective", False): "batch tiny decode collectives; widen TP only where cache sharding needs it",
}


def action_for(row: dict) -> str:
    return ACTIONS[(row["dominant"], row["kind"] == "train")]


def load_all(directory: str | Path) -> list[dict]:
    rows = []
    for p in sorted(Path(directory).glob("*.json")):
        art = json.loads(p.read_text())
        if "flops_per_device" not in art:
            continue
        rows.append(analyze_cell(art))
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | 6ND/HLO | roofline |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2f} |"
        )
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", type=str, default="artifacts/dryrun")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--csv", type=str, default=None)
    args = ap.parse_args()
    rows = load_all(args.dir)
    if args.md:
        print(to_markdown(rows))
    else:
        print("arch,shape,mesh,t_compute,t_memory,t_collective,dominant,"
              "useful_ratio,roofline_frac")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['t_compute_s']:.4f},"
                  f"{r['t_memory_s']:.4f},{r['t_collective_s']:.4f},"
                  f"{r['dominant']},{r['useful_ratio']:.3f},{r['roofline_frac']:.3f}")
    if args.csv:
        import csv

        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            for r in rows:
                r = dict(r)
                r["collective_counts"] = json.dumps(r["collective_counts"])
                w.writerow(r)


if __name__ == "__main__":
    main()
