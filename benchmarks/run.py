"""Benchmark harness — one function per paper table/claim (deliverable d).

Prints ``name,us_per_call,derived`` CSV rows.  Claims covered:

  §3 MegaScan  : near-zero tracing overhead; alignment accuracy; detection P/R
  §5 MegaDPP   : DFC/BFC memory + gradient-readiness trade (Fig. 3); async P2P
  §4 MegaFBD   : heterogeneous-cluster speedup; coordinator O(G) cost,
                 deadlock avoidance
  §6 MegaScope : capture overhead; compression ratios
  kernels      : reference-path timings (Pallas variants validated in tests)

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np


def _timeit(fn, n=5, warmup=2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def _row(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


# ------------------------------------------------------------- MegaScan ----


def bench_megascan_tracer_overhead() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.tracing import Tracer

    x = jnp.ones((256, 256))
    f = jax.jit(lambda x: jnp.tanh(x @ x))
    f(x).block_until_ready()
    base = _timeit(lambda: f(x).block_until_ready(), n=20)
    tr = Tracer(rank=0)

    def traced():
        with tr.scope("op", op="matmul"):
            f(x).block_until_ready()

    with_tr = _timeit(traced, n=20)
    ovh = (with_tr - base) / base * 100
    _row("megascan_tracer_overhead", with_tr, f"overhead_pct={ovh:.2f}")


def bench_megascan_alignment() -> None:
    from repro.core.simkit.workload import ModelProfile, Topology
    from repro.core.tracing import (
        ClockModel, align_clocks, apply_alignment, reconstruct_collectives,
        simulate_trace,
    )

    topo = Topology(dp=2, pp=2, tp=2)
    events, _ = simulate_trace(
        topo, ModelProfile(), n_micro=8, n_iters=2,
        clocks=ClockModel(offset_sigma=20e-3, drift_sigma=1e-4, seed=3),
    )
    t0 = time.perf_counter()
    aligned = apply_alignment(events, align_clocks(events))
    dt = (time.perf_counter() - t0) * 1e6

    def spread(evs):
        return float(np.median([
            max(i.ends.values()) - min(i.ends.values())
            for i in reconstruct_collectives(evs) if len(i.members) > 1
        ]))

    _row("megascan_clock_alignment", dt,
         f"median_skew_before_us={spread(events)*1e6:.1f};"
         f"after_us={spread(aligned)*1e6:.1f}")


def bench_megascan_detection() -> None:
    from repro.core.simkit.engine import FaultModel
    from repro.core.simkit.workload import ModelProfile, Topology
    from repro.core.tracing import (
        ClockModel, align_clocks, apply_alignment, detect, simulate_trace,
    )

    topo = Topology(dp=2, pp=2, tp=2)
    tp = fp = fn_ = 0
    t_us = []
    for seed in range(8):
        bad = seed % topo.world
        events, _ = simulate_trace(
            topo, ModelProfile(), n_micro=6, n_iters=2,
            faults=FaultModel(compute_slowdown={bad: 0.5}, jitter=0.01, seed=seed),
            clocks=ClockModel(seed=seed),
        )
        t0 = time.perf_counter()
        diag = detect(apply_alignment(events, align_clocks(events)), topo)
        t_us.append((time.perf_counter() - t0) * 1e6)
        tp += int(diag.slow_ranks == [bad])
        fp += len(set(diag.slow_ranks) - {bad})
        fn_ += int(bad not in diag.slow_ranks)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn_, 1)
    _row("megascan_detection", float(np.mean(t_us)),
         f"precision={prec:.2f};recall={rec:.2f};n=8")


# -------------------------------------------------------------- MegaDPP ----


def bench_dpp_schedules() -> None:
    from repro.core.dpp.planner import Planner
    from repro.core.simkit.workload import ModelProfile, Topology

    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(n_chunks=2, act_bytes=512 << 20)
    pl = Planner(topo, prof, n_micro=8, memory_cap=1 << 62)
    t0 = time.perf_counter()
    res = {w: pl._evaluate(w) for w in (1, 8)}
    dt = (time.perf_counter() - t0) * 1e6 / 2
    dfc, bfc = res[1], res[8]
    _row("dpp_dfc_vs_bfc", dt,
         f"dfc_peak_GiB={dfc[1]/2**30:.2f};bfc_peak_GiB={bfc[1]/2**30:.2f};"
         f"dfc_gradready_frac={dfc[2]/dfc[0]:.3f};"
         f"bfc_gradready_frac={bfc[2]/bfc[0]:.3f}")


def bench_dpp_zb_split() -> None:
    """ZB-inspired B/W split (paper §2.3.2 related work) vs plain 1F1B."""
    from repro.core.simkit.engine import Engine
    from repro.core.simkit.workload import ModelProfile, Topology, build_training_step

    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(fwd_time=1e-3, bwd_time=2e-3)
    t0 = time.perf_counter()
    mk_1f1b = Engine().run(build_training_step(topo, prof, n_micro=8)).makespan
    mk_zb = Engine().run(
        build_training_step(topo, prof, n_micro=8, schedule="zb")
    ).makespan
    dt = (time.perf_counter() - t0) * 1e6 / 2
    _row("dpp_zb_split", dt,
         f"1f1b_ms={mk_1f1b*1e3:.2f};zb_ms={mk_zb*1e3:.2f};"
         f"bubble_reduction={(1-mk_zb/mk_1f1b)*100:.1f}pct")


def bench_dpp_async_p2p() -> None:
    from repro.core.simkit.engine import Engine
    from repro.core.simkit.workload import ModelProfile, Topology, build_training_step

    topo = Topology(dp=1, pp=4, tp=1)
    prof = ModelProfile(p2p_bytes=64 << 20, fwd_time=5e-4, bwd_time=1e-3)

    def run(async_p2p, conc):
        order = build_training_step(topo, prof, n_micro=8, async_p2p=async_p2p)
        return Engine(link_concurrency=conc).run(order).makespan

    t0 = time.perf_counter()
    sync = run(False, 1)
    anc = run(True, 4)
    dt = (time.perf_counter() - t0) * 1e6 / 2
    _row("dpp_async_p2p", dt,
         f"sync_ms={sync*1e3:.2f};async_ms={anc*1e3:.2f};speedup={sync/anc:.2f}x")


def bench_dpp_executor() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.dpp.executor import build_time_table, pipeline_apply
    from repro.core.dpp.schedule import sched_wave

    S, C, n_micro, B, D = 4, 2, 8, 4, 64
    params = jax.random.normal(jax.random.PRNGKey(0), (S, C, D, D)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, D))
    mesh = jax.make_mesh((S,), ("stage",))
    table = build_time_table(sched_wave(n_micro, C, 2), S, C, n_micro)
    fn = jax.jit(lambda p, xx: pipeline_apply(
        p, xx, table, mesh=mesh, block_fn=lambda w, h: jnp.tanh(h @ w)))
    fn(params, x).block_until_ready()
    us = _timeit(lambda: fn(params, x).block_until_ready(), n=10)
    _row("dpp_pipeline_executor", us, f"stages={S};chunks={C};micro={n_micro}")


# -------------------------------------------------------------- MegaFBD ----


def bench_fbd_placement() -> None:
    from repro.core.fbd.ranks import (
        colocated_placement, evaluate_placement, plan_placement,
    )

    rows = []
    for frac_slow, slow in ((0.5, 0.4), (0.25, 0.6), (0.0, 1.0)):
        n = 8
        n_slow = int(n * frac_slow)
        speed = {d: 1.0 for d in range(n - n_slow)}
        speed |= {d: slow for d in range(n - n_slow, n)}
        t0 = time.perf_counter()
        dec = evaluate_placement(plan_placement(n, speed))
        col = evaluate_placement(colocated_placement(n, speed))
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((frac_slow, col / dec))
    _row("fbd_heterogeneous_speedup", dt,
         ";".join(f"slowfrac{f}={s:.2f}x" for f, s in rows))


def bench_fbd_coordinator() -> None:
    from repro.core.fbd.coordinator import (
        BitVectorCoordinator, ThreadProgram, run_fcfs, run_with_coordinator,
    )

    # O(G) state scaling
    sizes = {}
    for g in (8, 64, 512):
        sizes[g] = BitVectorCoordinator({i: (0, 1) for i in range(g)}, 2, 1).state_bytes
    # deadlock rates on the cross-control scenario
    groups = {1: (0, 2), 2: (1, 3)}
    programs = [ThreadProgram(0, 0, [1]), ThreadProgram(1, 0, [2]),
                ThreadProgram(2, 1, [1]), ThreadProgram(3, 1, [2])]
    dead = sum(run_fcfs(programs, groups, 2, arrival_seed=s) is None
               for s in range(32))
    t0 = time.perf_counter()
    for _ in range(20):
        run_with_coordinator(programs, groups, 2)
    us = (time.perf_counter() - t0) * 1e6 / 20
    _row("fbd_coordinator", us,
         f"state_bytes_8_64_512={sizes[8]}/{sizes[64]}/{sizes[512]};"
         f"fcfs_deadlock_rate={dead}/32;coordinator_deadlocks=0/32")


# ------------------------------------------------------------- MegaScope ---


def bench_scope_capture_overhead() -> None:
    import jax

    from repro.configs import get_config
    from repro.core.scope import ProbeSpec, ScopeCollector
    from repro.models import get_model, make_batch
    from repro.models import lm as lm_mod

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 64, jax.random.PRNGKey(1))
    f_off = jax.jit(lambda p, b: lm_mod.loss_fn(cfg, p, b)[0])
    scope = ScopeCollector(probes=[ProbeSpec("mlp_hidden", "stats"),
                                   ProbeSpec("att_resid", "stats")])
    f_on = jax.jit(lambda p, b: lm_mod.loss_fn(cfg, p, b, scope)[1]["captures"])
    f_off(params, batch).block_until_ready()
    jax.block_until_ready(f_on(params, batch))
    off = _timeit(lambda: f_off(params, batch).block_until_ready(), n=10)
    on = _timeit(lambda: jax.block_until_ready(f_on(params, batch)), n=10)
    _row("scope_capture_overhead", on,
         f"baseline_us={off:.1f};overhead_pct={(on-off)/off*100:.2f}")


def bench_scope_compression() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core.scope.compress import histogram, stats_of, subsample

    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512, 1024))
    full = x.size * 4
    t0 = time.perf_counter()
    s = stats_of(x)
    h = histogram(x)
    sub = subsample(x)
    jax.block_until_ready((s, h, sub))
    us = (time.perf_counter() - t0) * 1e6
    b_stats = sum(v.size * 4 for v in s.values())
    b_hist = h["hist"].size * 4 + h["edges"].size * 4
    b_sub = sub.size * 4
    _row("scope_compression", us,
         f"full_B={full};stats_B={b_stats}({full/b_stats:.0f}x);"
         f"hist_B={b_hist}({full/b_hist:.0f}x);sample_B={b_sub}({full/b_sub:.0f}x)")


# --------------------------------------------------------------- kernels ---


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.rglru.ref import rglru_ref
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    from repro.kernels.wkv6.ref import wkv6_ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 512, 1024), jnp.bfloat16)
    s = jnp.ones((1024,))
    f = jax.jit(lambda x: rmsnorm_ref(x, s))
    f(x).block_until_ready()
    us = _timeit(lambda: f(x).block_until_ready(), n=10)
    gbps = x.size * 2 * 2 / (us / 1e6) / 1e9
    _row("kernel_rmsnorm_ref", us, f"GBps={gbps:.1f};pallas=interpret-validated")

    B, S, H, K, D = 1, 512, 8, 2, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, K, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, K, D), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, scale=D**-0.5, impl="xla"))
    fa(q, k, v).block_until_ready()
    us = _timeit(lambda: fa(q, k, v).block_until_ready(), n=5)
    fl = 4 * B * S * S * H * D
    _row("kernel_flash_attention_ref", us, f"GFLOPs={fl/(us/1e6)/1e9:.1f}")

    BH, T, Kd = 8, 256, 64
    r = jax.random.normal(key, (BH, T, Kd))
    w = jnp.exp(-jnp.exp(jax.random.normal(key, (BH, T, Kd))))
    u = jax.random.normal(key, (BH, Kd))
    fw = jax.jit(lambda r, w: wkv6_ref(r, r, r, w, u)[0])
    fw(r, w).block_until_ready()
    us = _timeit(lambda: fw(r, w).block_until_ready(), n=3)
    _row("kernel_wkv6_ref", us, f"tokens_per_s={BH*T/(us/1e6):.0f}")

    a = jax.random.uniform(key, (4, 512, 1024), minval=0.5, maxval=0.99)
    b = jax.random.normal(key, (4, 512, 1024))
    fr = jax.jit(lambda a, b: rglru_ref(a, b)[0])
    fr(a, b).block_until_ready()
    us = _timeit(lambda: fr(a, b).block_until_ready(), n=3)
    _row("kernel_rglru_ref", us, f"tokens_per_s={4*512/(us/1e6):.0f}")


# ------------------------------------------------------------------ main ---


def main() -> None:
    print("name,us_per_call,derived")
    bench_megascan_tracer_overhead()
    bench_megascan_alignment()
    bench_megascan_detection()
    bench_dpp_schedules()
    bench_dpp_zb_split()
    bench_dpp_async_p2p()
    bench_dpp_executor()
    bench_fbd_placement()
    bench_fbd_coordinator()
    bench_scope_capture_overhead()
    bench_scope_compression()
    bench_kernels()
    # roofline summary (per-table artifact analysis lives in roofline.py)
    try:
        import os as _os

        from benchmarks.roofline import load_all

        art_dir = next(
            (d for d in ("artifacts/dryrun_final", "artifacts/dryrun")
             if _os.path.isdir(d)), "artifacts/dryrun",
        )
        rows = load_all(art_dir)
        if rows:
            best = max(rows, key=lambda r: r["roofline_frac"])
            _row("roofline_cells", 0.0,
                 f"n_cells={len(rows)};best={best['arch']}/{best['shape']}"
                 f"@{best['mesh']}={best['roofline_frac']:.2f}")
    except Exception as e:  # noqa: BLE001
        _row("roofline_cells", 0.0, f"skipped({type(e).__name__})")


if __name__ == "__main__":
    main()
