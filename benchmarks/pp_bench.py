"""Pipeline-parallel schedule sweep: bubble fraction + step time + parity.

Two layers of measurement, persisted to ``BENCH_pp.json``:

* **simkit** — every named traversal (``SCHEDULE_NAMES``, incl. the
  ZB-inspired B/W split) lowered via ``build_training_step`` and timed on the
  discrete-event engine: makespan + bubble fraction vs the zero-bubble ideal
  (``n_micro * (fwd + bwd)`` per stage);
* **executor** — the real thing: a tiny fp32 dense transformer trained
  through ``core.dpp.executor.pipeline_apply`` on a pp=2 host-device mesh,
  per-schedule forward-table bubble fraction, measured step wall time, and a
  hard parity gate: 3-step loss trajectory vs the non-pipelined reference
  step to fp32 tolerance (1f1b + wave at minimum — the acceptance bar);
* **composed** — dp x tp x pp points (``COMPOSED_POINTS``; dp=2,pp=2 at
  minimum) on one ``(stage, data, model)`` mesh, same three measurements.

    PYTHONPATH=src python benchmarks/pp_bench.py --out BENCH_pp.json
    make bench-pp
"""

from __future__ import annotations

import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dpp.executor import build_time_table, bubble_fraction
from repro.core.simkit.engine import Engine
from repro.core.simkit.workload import (
    ModelProfile,
    SCHEDULE_NAMES,
    Topology,
    build_training_step,
)
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_pipeline_mesh
from repro.parallel.plan import ParallelPlan, forward_order, resolve_plan
from repro.parallel.sharding import DEFAULT_RULES, axis_rules
from repro.train.optim import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step

TINY = ModelConfig(
    name="pp-bench-tiny", family="dense", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    attn_kv_chunk=32, logits_chunk=32, vocab_pad_to=64,
    param_dtype="float32", compute_dtype="float32", remat="none",
)

EXEC_SCHEDULES = ("1f1b", "wave", "dfc", "bfc")


def sim_sweep(pp: int, n_chunks: int, micros: tuple[int, ...]) -> dict:
    """Schedule comparison on the discrete-event engine (incl. zb)."""
    topo = Topology(dp=1, pp=pp, tp=1)
    prof = ModelProfile(n_chunks=n_chunks)
    out: dict[str, dict] = {}
    for name in SCHEDULE_NAMES:
        per_micro = {}
        for nm in micros:
            res = Engine().run(
                build_training_step(topo, prof, n_micro=nm, schedule=name)
            )
            ideal = nm * n_chunks * (prof.fwd_time + prof.bwd_time)
            per_micro[str(nm)] = {
                "makespan_ms": round(res.makespan * 1e3, 4),
                "bubble_frac": round(1.0 - ideal / res.makespan, 4),
            }
        out[name] = per_micro
    return out


def executor_sweep(
    pp: int, n_chunks: int, micros: tuple[int, ...], *, steps: int
) -> tuple[dict, dict]:
    """Real pipelined train steps on a host-device stage mesh + parity gate."""
    mesh = make_pipeline_mesh(pp)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    results: dict[str, dict] = {}
    parity: dict[str, dict] = {}

    for nm in micros:
        data = DataConfig(vocab_size=TINY.vocab_size, seq_len=32,
                          global_batch=nm)
        ds = SyntheticTokens(data)

        def losses_of(step_fn, n=steps):
            state = init_train_state(TINY, jax.random.PRNGKey(0))
            fn = jax.jit(step_fn)
            out, wall = [], []
            for i in range(n):
                batch = ds.batch_at(i)
                jax.block_until_ready(batch["tokens"])
                t0 = time.perf_counter()
                state, m = fn(state, batch)
                jax.block_until_ready(m["loss"])
                wall.append(time.perf_counter() - t0)
                out.append(float(m["loss"]))
            return out, wall

        ref_losses, _ = losses_of(make_train_step(TINY, ocfg))
        for name in EXEC_SCHEDULES:
            plan = resolve_plan(ParallelPlan(
                pp=pp, n_micro=nm, n_chunks=n_chunks, schedule=name,
            ))
            table = build_time_table(
                forward_order(plan), pp, n_chunks, nm
            )
            pp_losses, wall = losses_of(
                make_train_step(TINY, ocfg, plan=plan, mesh=mesh)
            )
            key = f"{name}@m{nm}"
            # steady-state step time: min over post-compile steps
            results.setdefault(name, {})[f"n_micro={nm}"] = {
                "wave": plan.wave,
                "table_steps": table.steps,
                "bubble_frac": round(bubble_fraction(table), 4),
                "step_ms_min": round(min(wall[1:] or wall) * 1e3, 3),
            }
            max_rel = max(
                abs(a - b) / max(abs(b), 1e-9)
                for a, b in zip(pp_losses, ref_losses)
            )
            parity[key] = {
                "ref_losses": [round(x, 6) for x in ref_losses],
                "pp_losses": [round(x, 6) for x in pp_losses],
                "max_rel_err": max_rel,
                "ok": bool(max_rel < 1e-4),
            }
    return results, parity


# (dp, tp, pp) points for the composed-mesh sweep; dp=2,pp=2 is the
# acceptance floor, the 2x2x2 point uses the full 8-device host fleet
COMPOSED_POINTS = ((2, 1, 2), (1, 2, 2), (2, 2, 2))


def composed_sweep(*, steps: int) -> dict:
    """dp x tp x pp composition on one (stage, data, model) host mesh.

    Same three measurements as the pp-only executor sweep — forward-table
    bubble fraction, steady-state step wall time, and the hard parity gate
    vs the fused single-device step — per composed point."""
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    data = DataConfig(vocab_size=TINY.vocab_size, seq_len=32, global_batch=8)
    ds = SyntheticTokens(data)

    def losses_of(step_fn, n=steps):
        state = init_train_state(TINY, jax.random.PRNGKey(0))
        fn = jax.jit(step_fn)
        out, wall = [], []
        for i in range(n):
            batch = ds.batch_at(i)
            jax.block_until_ready(batch["tokens"])
            t0 = time.perf_counter()
            state, m = fn(state, batch)
            jax.block_until_ready(m["loss"])
            wall.append(time.perf_counter() - t0)
            out.append(float(m["loss"]))
        return out, wall

    ref_losses, _ = losses_of(make_train_step(TINY, ocfg))
    out: dict[str, dict] = {}
    for dp, tp, pp in COMPOSED_POINTS:
        key = f"dp{dp}-tp{tp}-pp{pp}"
        if dp * tp * pp > len(jax.devices()):
            out[key] = {"skipped": f"needs {dp * tp * pp} devices"}
            continue
        plan = resolve_plan(ParallelPlan(dp=dp, tp=tp, pp=pp, n_micro=4 * dp))
        table = build_time_table(
            forward_order(plan), pp, plan.n_chunks, plan.n_micro_local
        )
        mesh = make_pipeline_mesh(pp, dp, tp)
        with mesh, axis_rules(mesh, DEFAULT_RULES):
            losses, wall = losses_of(
                make_train_step(TINY, ocfg, plan=plan, mesh=mesh)
            )
        max_rel = max(
            abs(a - b) / max(abs(b), 1e-9)
            for a, b in zip(losses, ref_losses)
        )
        out[key] = {
            "n_micro": plan.n_micro,
            "n_micro_local": plan.n_micro_local,
            "bubble_frac": round(bubble_fraction(table), 4),
            "step_ms_min": round(min(wall[1:] or wall) * 1e3, 3),
            "max_rel_err": max_rel,
            "ok": bool(max_rel < 1e-4),
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--n-chunks", type=int, default=2)
    ap.add_argument("--micros", type=str, default="4,8")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--out", default="", help="write BENCH_pp.json")
    args = ap.parse_args()
    micros = tuple(int(x) for x in args.micros.split(","))

    sim = sim_sweep(args.pp, args.n_chunks, micros)
    print("simkit sweep (makespan / bubble):")
    for name, per in sim.items():
        print(f"  {name:6s} " + "  ".join(
            f"m={nm}: {v['makespan_ms']:.2f}ms b={v['bubble_frac']:.3f}"
            for nm, v in per.items()))

    execu, parity = executor_sweep(
        args.pp, args.n_chunks, micros, steps=args.steps
    )
    print("executor sweep (pp=%d, chunks=%d):" % (args.pp, args.n_chunks))
    for name, per in execu.items():
        for k, v in per.items():
            print(f"  {name:6s} {k}: bubble={v['bubble_frac']:.3f} "
                  f"step={v['step_ms_min']:.2f}ms (T={v['table_steps']})")

    bad = {k: v for k, v in parity.items() if not v["ok"]}
    for k, v in parity.items():
        print(f"  parity {k}: max_rel_err={v['max_rel_err']:.2e} "
              f"{'OK' if v['ok'] else 'FAIL'}")

    composed = composed_sweep(steps=args.steps)
    print("composed sweep (dp x tp x pp on one (stage, data, model) mesh):")
    for key, v in composed.items():
        if "skipped" in v:
            print(f"  {key}: skipped ({v['skipped']})")
            continue
        print(f"  {key}: bubble={v['bubble_frac']:.3f} "
              f"step={v['step_ms_min']:.2f}ms "
              f"parity={v['max_rel_err']:.2e} "
              f"{'OK' if v['ok'] else 'FAIL'}")
        if not v["ok"]:
            bad[key] = v

    results = {
        "pp": args.pp,
        "n_chunks": args.n_chunks,
        "sim": sim,
        "executor": execu,
        "composed": composed,
        "parity": {k: v for k, v in sorted(parity.items())},
        "backend": jax.default_backend(),
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    if bad:
        raise SystemExit(
            f"pipeline-vs-reference parity FAILED for {sorted(bad)}"
        )


if __name__ == "__main__":
    main()
