"""Fault-tolerance gate: recovery overhead + checkpoint stall.

Three arms of the same CPU-smoke training run, wall-clocked end-to-end and
per-step (the timer plugin's ``wrap_step`` blocks on the loss):

* **clean** — metrics only, no checkpointing: the loss-parity reference;
* **ckpt**  — supervised (``ft`` module) with periodic async checkpoints:
  isolates the steady-state checkpoint cost, and the per-step entries
  separate the snapshot stall (deltas that include a ``save_async``) from
  ordinary steps;
* **chaos** — same, plus an injected crash mid-run: the loop restores the
  latest checkpoint and replays, and the extra wall over the **ckpt** arm
  is the true recovery overhead (restore + replayed steps).

Arms alternate across ``--repeats`` runs; wall floors (min) and per-step
medians (min-of-medians) score each arm.  Gates:

* the chaos arm completes every step with exactly one restart;
* its final loss matches the clean arm to fp32 tolerance (step-indexed
  batch determinism + sharding-preserving restore = same trajectory);
* recovery overhead stays under ``--max-overhead`` of the ckpt arm;
* the checkpoint-step stall stays under ``--max-stall-frac`` of an
  ordinary step.

    PYTHONPATH=src python benchmarks/ft_bench.py --out BENCH_ft.json
    make bench-ft
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro.app.config import build_run_config
from repro.app.plugins import ModulePlugin, build_plugins
from repro.app.session import Session

WARMUP = 2  # leading deltas dropped from per-step stats (compile settles)


class _StepTimer(ModulePlugin):
    name = "bench-timer"

    def __init__(self, run_cfg):
        super().__init__(run_cfg)
        self.entries: list[float] = []

    def wrap_step(self, step_fn):
        def timed(state, batch):
            out = step_fn(state, batch)
            jax.block_until_ready(out[1]["loss"])
            self.entries.append(time.perf_counter())
            return out

        return timed


def _arm(kind: str, *, arch: str, steps: int, ckpt_every: int,
         crash_at: int, workdir: Path) -> dict:
    sets = [
        f"train.steps={steps}", "train.seq_len=128", "train.global_batch=4",
        f"train.log_every={steps}",
    ]
    modules: tuple[str, ...] = ("metrics",)
    if kind != "clean":
        ckpt_dir = workdir / f"ckpt-{kind}"
        sets += [f"train.ckpt_dir={ckpt_dir}",
                 f"train.ckpt_every={ckpt_every}"]
        modules = ("metrics", "ft")
    if kind == "chaos":
        sets += [f"ft.chaos.crash_at_step={crash_at}"]
    cfg = build_run_config("train", arch=arch, smoke=True, sets=sets)
    timer = _StepTimer(cfg)
    session = Session(cfg, plugins=build_plugins(modules, cfg) + [timer])
    t0 = time.perf_counter()
    session.run()
    wall = time.perf_counter() - t0

    deltas = np.diff(timer.entries)
    steady = deltas[WARMUP:] if len(deltas) > 2 * WARMUP else deltas
    out = {
        "wall_s": round(wall, 3),
        "steps_run": len(timer.entries),
        "step_ms_median": round(float(np.median(steady)) * 1e3, 3),
        "final_loss": session.results["history"][-1]["loss"],
        "final_step": session.results["history"][-1]["step"],
    }
    if kind == "ckpt":
        # a save_async issued after step s lands in that step's exit delta:
        # snapshot-to-host runs synchronously before the thread hands off
        is_ckpt = np.array([(k + 1) % ckpt_every == 0
                            for k in range(len(deltas))])[WARMUP:]
        if is_ckpt.any() and (~is_ckpt).any():
            out["ckpt_step_ms"] = round(float(np.median(steady[is_ckpt])) * 1e3, 3)
            out["plain_step_ms"] = round(float(np.median(steady[~is_ckpt])) * 1e3, 3)
    if kind != "clean":
        ft = session.results["ft"]
        out["restarts"] = ft["restarts"]
        out["timeline_events"] = [t["event"] for t in ft["timeline"]]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--crash-at", type=int, default=10,
                    help="chaos arm: injected crash step (restores to the "
                         "floor multiple of --ckpt-every and replays)")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--max-overhead", type=float, default=1.0,
                    help="gate: chaos/ckpt wall - 1 must stay below this")
    ap.add_argument("--max-stall-frac", type=float, default=2.0,
                    help="gate: (ckpt-step - plain-step)/plain-step cap")
    ap.add_argument("--out", default="", help="write BENCH_ft.json")
    args = ap.parse_args()

    arms: dict[str, list[dict]] = {"clean": [], "ckpt": [], "chaos": []}
    with tempfile.TemporaryDirectory() as td:
        workdir = Path(td)
        for rep in range(args.repeats):
            for kind in ("clean", "ckpt", "chaos"):
                # each run gets a fresh checkpoint dir (no cross-run resume)
                d = workdir / f"rep{rep}"
                d.mkdir(exist_ok=True)
                arms[kind].append(_arm(
                    kind, arch=args.arch, steps=args.steps,
                    ckpt_every=args.ckpt_every, crash_at=args.crash_at,
                    workdir=d,
                ))
                r = arms[kind][-1]
                print(f"  rep {rep} {kind:5s}: {r['wall_s']:.2f}s wall, "
                      f"{r['step_ms_median']:.1f} ms/step"
                      + (f", {r['restarts']} restart(s)"
                         if "restarts" in r else ""))

    clean = min(arms["clean"], key=lambda r: r["wall_s"])
    ckpt = min(arms["ckpt"], key=lambda r: r["wall_s"])
    chaos = min(arms["chaos"], key=lambda r: r["wall_s"])

    recovery_overhead = chaos["wall_s"] / ckpt["wall_s"] - 1.0
    loss_ok = bool(np.isclose(
        chaos["final_loss"], clean["final_loss"], rtol=1e-5))
    complete_ok = (chaos["final_step"] == args.steps
                   and all(r["restarts"] == 1 for r in arms["chaos"]))
    stall_frac = None
    if "ckpt_step_ms" in ckpt:
        stall_frac = (ckpt["ckpt_step_ms"] - ckpt["plain_step_ms"]) \
            / ckpt["plain_step_ms"]
    stall_ok = stall_frac is None or stall_frac < args.max_stall_frac
    overhead_ok = recovery_overhead < args.max_overhead
    ok = loss_ok and complete_ok and stall_ok and overhead_ok

    print(f"clean : {clean['wall_s']:.2f}s  loss {clean['final_loss']:.6f}")
    print(f"ckpt  : {ckpt['wall_s']:.2f}s"
          + (f"  ckpt-step {ckpt['ckpt_step_ms']:.1f} ms vs "
             f"plain {ckpt['plain_step_ms']:.1f} ms "
             f"(stall {stall_frac * 100:+.1f}%, "
             f"gate < {args.max_stall_frac * 100:.0f}%)"
             if stall_frac is not None else ""))
    print(f"chaos : {chaos['wall_s']:.2f}s  loss {chaos['final_loss']:.6f}  "
          f"recovery overhead {recovery_overhead * 100:+.1f}% "
          f"(gate < {args.max_overhead * 100:.0f}%)")
    print(f"loss parity {'OK' if loss_ok else 'FAIL'}, "
          f"completion {'OK' if complete_ok else 'FAIL'} -> "
          f"{'OK' if ok else 'FAIL'}")

    results = {
        "arch": args.arch,
        "steps": args.steps,
        "ckpt_every": args.ckpt_every,
        "crash_at": args.crash_at,
        "repeats": args.repeats,
        "clean": clean,
        "ckpt": ckpt,
        "chaos": chaos,
        "recovery_overhead_frac": round(recovery_overhead, 4),
        "ckpt_stall_frac": round(stall_frac, 4) if stall_frac is not None else None,
        "loss_parity_ok": loss_ok,
        "completion_ok": complete_ok,
        "max_overhead": args.max_overhead,
        "max_stall_frac": args.max_stall_frac,
        "ok": bool(ok),
        "backend": jax.default_backend(),
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    if not ok:
        raise SystemExit("ft bench gate failed (see above)")


if __name__ == "__main__":
    main()
